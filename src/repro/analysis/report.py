"""Audit findings and the per-model report the CLI emits.

A :class:`Finding` is one fact the static passes established about a plan
— an error (the plan is unsafe to serve), a warning (suspicious but not
disqualifying), or info (a bound worth recording, e.g. the peak arena).
The :class:`AuditReport` aggregates the four passes' findings per model
and route and renders them as JSON (machine-checkable CI artifact) or
markdown (the human report README links to).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

ERROR = "error"
WARNING = "warning"
INFO = "info"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verdict from a static pass.

    ``code`` namespaces the check (``V``\\*: graph verifier, ``A``\\*:
    arena liveness, ``R``\\*: no-retrace auditor, ``B``\\*: pad budget),
    ``where`` names the op/tensor it anchors to, and ``message`` states
    the fact — severities follow the module constants above.
    """

    severity: str
    code: str
    where: str
    message: str

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"[{self.severity}] {self.code} {self.where}: {self.message}"


def errors(findings: List[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == ERROR]


@dataclasses.dataclass
class RouteReport:
    """One route's audit results for one model (per-call / batched /
    paged lower from the same plan but have different static bounds)."""

    route: str                      # "per-call" | "batched[b=N]" | "paged"
    findings: List[Finding] = dataclasses.field(default_factory=list)
    arena: Dict[str, Any] = dataclasses.field(default_factory=dict)
    pads: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not errors(self.findings)


@dataclasses.dataclass
class AuditReport:
    """Everything the auditor established about one model's plan."""

    model: str
    use_pallas: bool
    verifier: List[Finding] = dataclasses.field(default_factory=list)
    routes: List[RouteReport] = dataclasses.field(default_factory=list)
    retrace: Dict[str, Any] = dataclasses.field(default_factory=dict)
    retrace_findings: List[Finding] = dataclasses.field(default_factory=list)
    # plan content address (repro.analysis.fingerprint) — lets the AOT
    # cache cross-check its manifest against this audit (finding C005)
    fingerprint: Optional[str] = None

    @property
    def findings(self) -> List[Finding]:
        out = list(self.verifier) + list(self.retrace_findings)
        for r in self.routes:
            out.extend(r.findings)
        return out

    @property
    def ok(self) -> bool:
        return not errors(self.findings)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "model": self.model,
            "use_pallas": self.use_pallas,
            "fingerprint": self.fingerprint,
            "ok": self.ok,
            "verifier": [f.as_dict() for f in self.verifier],
            "retrace": self.retrace,
            "retrace_findings": [f.as_dict()
                                 for f in self.retrace_findings],
            "routes": [{
                "route": r.route,
                "ok": r.ok,
                "arena": r.arena,
                "pads": r.pads,
                "findings": [f.as_dict() for f in r.findings],
            } for r in self.routes],
        }


def to_json(reports: List[AuditReport]) -> str:
    doc = {
        "ok": all(r.ok for r in reports),
        "models": [r.as_dict() for r in reports],
    }
    return json.dumps(doc, indent=2, sort_keys=True)


def _fmt_bytes(n: Optional[int]) -> str:
    if n is None:
        return "-"
    return f"{n / 1024:.1f} kB" if n >= 1024 else f"{n} B"


def to_markdown(reports: List[AuditReport]) -> str:
    lines: List[str] = ["# Static plan audit", ""]
    for rep in reports:
        route_kind = "pallas+layout" if rep.use_pallas else "plain"
        status = "OK" if rep.ok else "FAIL"
        lines.append(f"## {rep.model} ({route_kind}) — {status}")
        lines.append("")
        lines.append("| route | peak arena (static) | peak arena (measured)"
                     " | pads (budget) | pads (traced) |")
        lines.append("|---|---|---|---|---|")
        for r in rep.routes:
            budget = r.pads.get("budget")
            lines.append("| {} | {} | {} | {} | {} |".format(
                r.route,
                _fmt_bytes(r.arena.get("static_peak_bytes")),
                _fmt_bytes(r.arena.get("measured_peak_bytes")),
                "-" if budget is None else budget,
                r.pads.get("traced", "-")))
        lines.append("")
        if rep.retrace:
            lines.append(
                "- no-retrace: buckets {} / staged pads {} — {}".format(
                    rep.retrace.get("reachable_buckets"),
                    rep.retrace.get("reachable_stage_keys"),
                    "proved" if rep.retrace.get("ok") else "NOT proved"))
        shown = [f for f in rep.findings if f.severity != INFO]
        for f in shown:
            lines.append(f"- {f}")
        lines.append("")
    return "\n".join(lines)
