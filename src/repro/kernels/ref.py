"""Pure-jnp oracles for every Pallas kernel (no pallas imports here).

These mirror the kernels' contracts exactly: folded-constant int8 math with
explicit (lo, hi) clamp bounds. The engine-level references live in
``repro.core.ops_ref``; these oracles re-express them in the kernels'
pre-padded / pre-broadcast argument convention so the per-kernel allclose
tests compare like for like.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

I8_MIN, I8_MAX = -128, 127


def _requant(acc, sum_x, bias_term, rescale, w_sum_zx, const_off, z_w, lo, hi):
    inner = acc - z_w * sum_x - w_sum_zx + const_off
    y = bias_term + rescale * inner.astype(jnp.float32)
    y = jnp.clip(y, lo, hi)
    return jnp.clip(jnp.round(y), I8_MIN, I8_MAX).astype(jnp.int8)


def qmatmul_ref(x_q, w_q, bias_term, rescale, w_sum_zx, const_off, z_w,
                *, lo=-jnp.inf, hi=jnp.inf):
    """Oracle for kernels.qmatmul.qmatmul and paged_matmul.paged_qmatmul."""
    x32 = x_q.astype(jnp.int32)
    acc = x32 @ w_q.astype(jnp.int32)
    sum_x = jnp.sum(x32, axis=-1, keepdims=True)
    n = w_q.shape[1]

    def row(v, dtype):
        return jnp.broadcast_to(jnp.asarray(v, dtype).reshape(-1), (n,))

    return _requant(acc, sum_x, row(bias_term, jnp.float32),
                    row(rescale, jnp.float32), row(w_sum_zx, jnp.int32),
                    row(const_off, jnp.int32), row(z_w, jnp.int32), lo, hi)


def fmatmul_ref(x, w):
    """Oracle for kernels.qmatmul.fmatmul."""
    return jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def qdwconv_ref(x_q, w_q, bias_term, rescale, w_sum_zx, const_off, z_w,
                *, stride, lo=-jnp.inf, hi=jnp.inf):
    """Oracle for kernels.qdwconv.qdwconv. x_q (B,H,W,C) pre-padded,
    w_q (kh,kw,C); VALID conv."""
    kh, kw, c = w_q.shape
    sh, sw = stride
    b, H, W, _ = x_q.shape
    oh = (H - kh) // sh + 1
    ow = (W - kw) // sw + 1
    x32 = x_q.astype(jnp.int32)
    w32 = w_q.astype(jnp.int32)
    acc = jnp.zeros((b, oh, ow, c), jnp.int32)
    sum_x = jnp.zeros((b, oh, ow, c), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(
                x32, (0, i, j, 0),
                (b, i + (oh - 1) * sh + 1, j + (ow - 1) * sw + 1, c),
                (1, sh, sw, 1))
            acc = acc + sl * w32[i, j]
            sum_x = sum_x + sl

    def row(v, dtype):
        return jnp.broadcast_to(jnp.asarray(v, dtype).reshape(-1), (c,))

    return _requant(acc, sum_x, row(bias_term, jnp.float32),
                    row(rescale, jnp.float32), row(w_sum_zx, jnp.int32),
                    row(const_off, jnp.int32), row(z_w, jnp.int32), lo, hi)
