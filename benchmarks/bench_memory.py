"""Figs. 9/10 — memory usage per engine.

Interpreter (TFLM architecture): weights + arena (persists the whole
inference) + runtime structures.
Compiled (MicroFlow): weights + folded constants + transient stack peak
(zero residual after inference).
The byte-exact planner numbers are the RAM columns; XLA's own
memory_analysis of the compiled executable is reported alongside.
"""
from __future__ import annotations

import numpy as np

from repro.analysis import arena_liveness, measure_live_bytes
from repro.core import CompiledModel
from repro.core.memory import memory_report, plan_paged

from .common import csv_line, paper_models


def main(fast: bool = False):
    lines = []
    models = paper_models(batch=1)
    for name, m in models.items():
        qg = m["int8"]
        rep = memory_report(qg)
        # Fig 9/10 "Flash": weights + code; "RAM": arena vs stack peak
        lines.append(csv_line(
            f"memory/{name}_weights_kB", None,
            f"{rep.weight_bytes/1024:.2f}"))
        lines.append(csv_line(
            f"memory/{name}_interp_arena_kB", None,
            f"{rep.arena_bytes/1024:.2f}"))
        lines.append(csv_line(
            f"memory/{name}_compiled_stack_peak_kB", None,
            f"{rep.stack_peak_bytes/1024:.2f}"))
        lines.append(csv_line(
            f"memory/{name}_compiled_stack_fused_kB", None,
            f"{rep.stack_peak_fused/1024:.2f}"))
        lines.append(csv_line(
            f"memory/{name}_folded_consts_kB", None,
            f"{rep.folded_const_bytes/1024:.2f}"))
        cm = CompiledModel(qg)
        mem = cm.memory_analysis()
        lines.append(csv_line(
            f"memory/{name}_xla_temp_kB", None,
            f"{mem.temp_size_in_bytes/1024:.2f}"))
        # Static arena bound from the plan auditor vs the measured walk of
        # the real lowerings — ratio lands in BENCH_runtime.json and
        # tools/check_bench.py fails the gate if it drifts past 10%
        # (the static shape model no longer matches what lowers).
        bound = arena_liveness(cm.exec_plan)
        measured = measure_live_bytes(cm.exec_plan)
        lines.append(csv_line(
            f"memory/{name}_arena_peak_kB", None,
            f"{bound.peak_bytes/1024:.2f}",
            ratio=(bound.peak_bytes / measured) if measured else None))
    return lines


if __name__ == "__main__":
    main()
