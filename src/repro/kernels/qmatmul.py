"""Quantized int8 matmul Pallas kernel — the FullyConnected hot-spot (Eq. 3).

TPU adaptation of the paper's FC kernel: instead of the MCU's scalar MAC
loop, the contraction is blocked into MXU-aligned (128×128) VMEM tiles,
accumulated in int32, with the compile-time folded constants of Eq. (4)
applied once per output tile at the final K step. The input-dependent
``z_W · Σ_k X`` term is accumulated alongside the main product, so the kernel
remains a single pass over the data.

Grid: (M/bm, N/bn, K/bk), K innermost — each (i, j) output tile streams its
K-line of x/w tiles HBM→VMEM (this is the paper's paging idea applied to the
contraction dimension; see paged_matmul.py for the output-dimension paging of
Fig. 6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I8_MIN, I8_MAX = -128, 127


def _qmatmul_kernel(x_ref, w_ref, bias_ref, resc_ref, wsum_ref, coff_ref,
                    zw_ref, out_ref, acc_ref, sumx_ref, *, n_k, lo, hi,
                    n_true):
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)

    x = x_ref[...].astype(jnp.int32)          # (bm, bk)
    w = w_ref[...].astype(jnp.int32)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    sumx_ref[...] += jnp.sum(x, axis=1, keepdims=True)   # (bm, 1)

    @pl.when(k == n_k - 1)
    def _finish():
        inner = (acc_ref[...]
                 - zw_ref[...] * sumx_ref[...]      # z_W Σ_k X  (input-dep.)
                 - wsum_ref[...]                    # z_X Σ_k W  (folded)
                 + coff_ref[...])                   # n z_X z_W  (folded)
        y = bias_ref[...] + resc_ref[...] * inner.astype(jnp.float32)
        y = jnp.clip(y, lo, hi)                     # fused activation
        q = jnp.clip(jnp.round(y), I8_MIN, I8_MAX).astype(jnp.int8)
        if n_true is not None:
            # Padded-layout contract: lanes >= n_true carry ZERO, so the next
            # layer's K-padding contributes nothing to its Σ X W or Σ X and
            # activations can stay tile-resident across layers.
            bm, bn = q.shape
            col = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + j * bn
            q = jnp.where(col < n_true, q, 0)
        out_ref[...] = q


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "lo", "hi", "n_true", "interpret"))
def qmatmul(x_q, w_q, bias_term, rescale, w_sum_zx, const_off, z_w,
            *, bm=128, bn=128, bk=128, lo=-jnp.inf, hi=jnp.inf,
            n_true=None, interpret=False):
    """x_q (M, K) int8, w_q (K, N) int8, per-channel consts (N,) -> (M, N) int8.

    M, K, N must be multiples of the block sizes (ops.qmatmul_folded pads).
    ``n_true``: when set, output lanes >= n_true are written as zero — the
    padded-layout contract that lets chained layers skip the pad/slice pair.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (x_q.shape, w_q.shape, bm, bn, bk)
    n_k = k // bk

    def row(v, dtype):
        return jnp.broadcast_to(jnp.asarray(v, dtype).reshape(-1), (n,)) \
                  .reshape(1, n)

    consts = (row(bias_term, jnp.float32), row(rescale, jnp.float32),
              row(w_sum_zx, jnp.int32), row(const_off, jnp.int32),
              row(z_w, jnp.int32))
    const_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))

    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, n_k=n_k, lo=lo, hi=hi,
                          n_true=n_true),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            const_spec, const_spec, const_spec, const_spec, const_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x_q, w_q, *consts)


# ---------------------------------------------------------------------------
# Generic float matmul kernel (used by the float FC path and dtype sweeps).
# ---------------------------------------------------------------------------

def _fmatmul_kernel(x_ref, w_ref, out_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fmatmul(x, w, *, bm=128, bn=128, bk=128, interpret=False):
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_fmatmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
