"""Multi-model serving registry for the compiled TinyML engine.

One process serves several compiled models (the paper's sine / speech /
person trio by default), each behind its own
:class:`repro.serve.scheduler.MicroBatcher`:

* **Warm-up compilation** — ``register`` builds the ``CompiledModel`` and
  AOT-compiles the batch-1 executable plus every power-of-two bucket up to
  the model's ``max_batch`` — every bucket lowered from the model's single
  ``ExecutionPlan``, layout plan included, plus the staged entry pads
  (fused bucket zero-fill + lane pad) for every batch size below it — so
  the first request is as fast as the millionth (all compilation ahead of
  serving, the MicroFlow discipline applied to the fleet).
* **Shared dispatch stage** — the registry can hand every batcher one
  :class:`repro.serve.executor.InferenceExecutor`. With the default
  ``InlineExecutor`` flushes run on the event loop (deterministic); with a
  shared ``ThreadPoolExecutorBackend`` flushes from *all* models
  interleave on one worker pool, so one model's device call no longer
  blocks another model's arrival processing. The registry owns the
  executor's lifecycle: ``stop()`` closes it after the batchers drain.
* **Admission control** — ``infer``/``submit`` reject unknown models
  (``KeyError``) and route each request through its model's priority
  classes: at capacity the batcher sheds by priority (lowest-priority
  pending request evicted with ``PreemptedError``) or refuses the
  newcomer with :class:`QueueFullError`. Together with the engine's
  static buffers and the joint ``pending + in_flight`` bound this keeps
  resident memory flat under overload.
* **Metrics** — per-model :class:`repro.serve.metrics.ModelMetrics`
  snapshots (p50/p95/p99 latency, throughput, batch occupancy, per-class
  SLO attainment) via :meth:`snapshot`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import CompiledModel
from .executor import InferenceExecutor  # noqa: F401  (re-export)
from .metrics import ModelMetrics
from .scheduler import (Clock, ClassPolicy, MicroBatcher,  # noqa: F401
                        PreemptedError, QueueFullError)


@dataclasses.dataclass
class _Entry:
    name: str
    model: CompiledModel
    batcher: MicroBatcher


class ServingRegistry:
    """Named compiled models, each behind a dynamic micro-batcher.

    ``executor`` (optional) is shared by every registered model's batcher
    and closed by :meth:`stop`; ``executor_workers`` (optional) builds a
    shared ``ThreadPoolExecutorBackend`` of that width when no explicit
    ``executor`` is given (the ``REPRO_EXECUTOR_WORKERS`` env var sets the
    default width when neither is passed); ``classes`` (optional ``{name:
    ClassPolicy}``) is the default priority-class table each batcher
    starts from — executor and classes can be overridden per model in
    :meth:`register`.
    """

    def __init__(self, *, clock: Optional[Clock] = None, max_batch: int = 32,
                 max_delay_s: float = 0.002, max_queue: int = 256,
                 executor: Optional[InferenceExecutor] = None,
                 executor_workers: Optional[int] = None,
                 classes: Optional[dict] = None, tracer=None,
                 cache=None, cache_dir: Optional[str] = None,
                 audit_path: Optional[str] = None):
        self.clock = clock or Clock()
        if executor is None and executor_workers is not None:
            # convenience: size the shared off-loop pool without importing
            # the backend (the env default REPRO_EXECUTOR_WORKERS applies
            # when neither is given and an explicit backend is built)
            from .executor import ThreadPoolExecutorBackend
            executor = ThreadPoolExecutorBackend(max_workers=executor_workers)
        self.executor = executor
        # one repro.obs.Tracer shared by every batcher (None = tracing off)
        self.tracer = tracer
        if cache is None and cache_dir is not None:
            # convenience mirror of executor_workers: a directory is
            # enough to opt the whole registry into persistent AOT boots
            from .aotcache import AotCache
            cache = AotCache(cache_dir, audit_path=audit_path)
        self.cache = cache
        self._defaults = dict(max_batch=max_batch, max_delay_s=max_delay_s,
                              max_queue=max_queue, classes=classes,
                              tracer=tracer, cache=cache)
        self._entries: dict = {}
        self._started = False
        self._stopped = False

    # -- registration / lifecycle ----------------------------------------
    def register(self, name: str, model: CompiledModel, *,
                 warmup: bool = True, **overrides) -> CompiledModel:
        """Admit ``model`` (an int8 ``CompiledModel``) under ``name``.
        ``overrides`` replace the registry-level batcher defaults
        (``max_batch`` / ``max_delay_s`` / ``max_queue`` / ``classes`` /
        ``executor`` / ``tracer``) for this model."""
        if name in self._entries:
            raise ValueError(f"model {name!r} already registered")
        kw = {**self._defaults, "executor": self.executor, **overrides}
        batcher = MicroBatcher.for_model(
            model, warmup=warmup, name=name, clock=self.clock,
            metrics=ModelMetrics(now=self.clock.now()), **kw)
        self._entries[name] = _Entry(name, model, batcher)
        if self._started:  # late registration joins a running registry
            batcher.start()
        return model

    def models(self) -> tuple:
        return tuple(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def start(self) -> "ServingRegistry":
        if self._stopped:
            raise RuntimeError("registry is stopped (stop() is terminal); "
                               "build a new ServingRegistry")
        for e in self._entries.values():
            e.batcher.start()
        self._started = True
        return self

    @property
    def stopped(self) -> bool:
        """Whether :meth:`stop` has run (stop is terminal and
        idempotent)."""
        return self._stopped

    async def stop(self, drain: bool = True) -> None:
        """Terminal: drains (or cancels) every batcher, closes every
        executor handed to the registry (the registry-level one AND any
        per-model ``register(..., executor=...)`` override — handing an
        executor to the registry transfers ownership), and shuts the
        registry down for good — serving again means building a new
        registry (warm-ups are per-``CompiledModel``, so the models
        themselves can be re-registered cheaply).

        Idempotent: a second stop (e.g. ``__aexit__`` after an explicit
        ``stop()``) returns immediately — batchers are not re-closed and
        no metric is counted twice."""
        if self._stopped:
            return
        self._stopped = True
        for e in self._entries.values():
            await e.batcher.close(drain=drain)
        owned = {id(self.executor): self.executor} \
            if self.executor is not None else {}
        for e in self._entries.values():  # per-model overrides included;
            owned[id(e.batcher.executor)] = e.batcher.executor  # close()
        for ex in owned.values():         # is idempotent and a no-op for
            ex.close()                    # InlineExecutor
        self._started = False

    async def __aenter__(self):
        return self.start()

    async def __aexit__(self, *exc):
        await self.stop()

    # -- serving ----------------------------------------------------------
    def _entry(self, name: str) -> _Entry:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"unknown model {name!r}; "
                           f"registered: {sorted(self._entries)}") from None

    def submit(self, name: str, x, cls: str = "default",
               deadline_s: Optional[float] = None,
               wall_deadline_s: Optional[float] = None):
        """Admission-controlled enqueue under priority class ``cls``;
        returns the request's future. Raises ``KeyError`` for
        unregistered models or unknown classes, ``QueueFullError`` when
        the model's bounded queue sheds the request (a lower-priority
        pending request may be preempted in its favor instead).
        ``wall_deadline_s`` caps the request's end-to-end wall time
        (defaults to the class's ``slo_s``): still pending past it, the
        request is expired with ``DeadlineExceededError`` instead of
        dispatched."""
        if not self._started:
            raise RuntimeError("registry not started (use `async with` "
                               "or call start())")
        return self._entry(name).batcher.submit(
            x, cls=cls, deadline_s=deadline_s,
            wall_deadline_s=wall_deadline_s)

    async def infer(self, name: str, x, cls: str = "default",
                    deadline_s: Optional[float] = None,
                    wall_deadline_s: Optional[float] = None):
        return await self.submit(name, x, cls=cls, deadline_s=deadline_s,
                                 wall_deadline_s=wall_deadline_s)

    # -- dtype helpers (requests travel in graph dtype) --------------------
    def quantize_input(self, name: str, x):
        """Float sample -> graph-dtype sample for ``submit``/``infer``."""
        g = self._entry(name).model.graph
        t = g.tensor(g.inputs[0])
        x = np.asarray(x, np.float32).reshape(t.shape)
        return np.asarray(t.qparams.quantize(x)) if t.dtype == "int8" else x

    def dequantize_output(self, name: str, y):
        g = self._entry(name).model.graph
        t = g.tensor(g.outputs[0])
        y = np.asarray(y)
        return (t.qparams.dequantize(y) if t.dtype == "int8"
                else y.astype(np.float32))

    # -- observability -----------------------------------------------------
    def metrics(self, name: str) -> ModelMetrics:
        return self._entry(name).batcher.metrics

    def snapshot(self) -> dict:
        """{model: metrics snapshot} for every registered model."""
        now = self.clock.now()
        return {e.name: e.batcher.metrics.snapshot(now)
                for e in self._entries.values()}

    def engines(self) -> dict:
        """Per-model compile/cache accounting straight off the engines:
        ``compile_events`` (real XLA compiles — zero after a warm cache
        boot), the typed ``compile_log`` tail, and the hit/miss/store
        ``cache_events`` split. Duck-typed stand-ins without the counters
        report empty."""
        out = {}
        for e in self._entries.values():
            m = e.model
            out[e.name] = {
                "compile_events": getattr(m, "compile_events", 0),
                "cache_events": dict(getattr(m, "cache_events", {}) or {}),
                "compile_log": list(getattr(m, "compile_log", ()) or ())[-32:],
            }
        return out

    def cache_status(self) -> Optional[dict]:
        """The registry-level cache's counters plus each model's boot
        outcome (``None`` when no cache is configured)."""
        if self.cache is None:
            return None
        status = dict(self.cache.stats())
        boots = {}
        for e in self._entries.values():
            res = getattr(e.model, "last_cache_result", None)
            boots[e.name] = res.to_dict() if res is not None else None
        status["boots"] = boots
        return status

    def openmetrics(self) -> str:
        """OpenMetrics text exposition of every model's metrics (plus the
        per-stage latency histograms when a tracer is installed) — ready
        to serve from a scrape endpoint."""
        from repro.obs.export import openmetrics
        return openmetrics(self.snapshot(), tracer=self.tracer,
                           engines=self.engines(),
                           cache=self.cache_status())

    def telemetry(self) -> dict:
        """Structured JSON snapshot unifying metrics, trace histograms,
        the flight recorder's status, and the engines' compile/cache
        accounting (``repro.obs.export``)."""
        from repro.obs.export import json_snapshot
        flight = self.tracer.flight if self.tracer is not None else None
        return json_snapshot(self.snapshot(), tracer=self.tracer,
                             flight=flight, engines=self.engines(),
                             cache=self.cache_status())


def build_paper_registry(names=("sine", "speech", "person"), *,
                         calib_samples: int = 8, seed: int = 0,
                         use_pallas: bool = False, layout_plan: bool = True,
                         **registry_kw) -> ServingRegistry:
    """Registry serving the paper's models (Table 3), quantized with
    calibrated-random representative data exactly as the benchmarks do.

    ``use_pallas``/``layout_plan`` select the engine route every served
    bucket lowers through (see ``repro.core.engine.ExecutionPlan``): with
    ``use_pallas=True`` the warm-up AOT-compiles layout-planned bucket
    executables — activations stay lane-padded across the whole batched
    graph — while ``layout_plan=False`` keeps the per-call pad/slice route
    for A/B comparison (``benchmarks.bench_serve`` records both).
    ``registry_kw`` reaches :class:`ServingRegistry` — including
    ``executor`` (shared off-loop dispatch) and ``classes`` (priority
    table)."""
    from repro.configs.paper_models import PAPER_MODELS
    from repro.core.quantize import quantize_graph

    gens = {
        "sine": lambda rng, n: rng.uniform(0, 2 * np.pi, (n, 1)).astype("f"),
        "speech": lambda rng, n: rng.normal(0, 1, (n, 49, 40, 1)).astype("f"),
        "person": lambda rng, n: rng.normal(0, 1, (n, 96, 96, 1)).astype("f"),
    }
    reg = ServingRegistry(**registry_kw)
    rng = np.random.default_rng(seed)
    for name in names:
        g = PAPER_MODELS[name](batch=1)
        rep = [gens[name](rng, 1) for _ in range(calib_samples)]
        reg.register(name, CompiledModel(quantize_graph(g, rep),
                                         use_pallas=use_pallas,
                                         layout_plan=layout_plan))
    return reg
