"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b-smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Runs real training on the local device(s); any registered arch id works,
``<id>-smoke`` selects the reduced variant. On a real TPU slice the same
entry point runs under the production mesh (--mesh single|multi).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM, frontend_stub
from repro.launch import sharding as SH
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.train import checkpoint as CKPT
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    dtype = jnp.dtype(args.dtype)
    mesh = (make_local_mesh() if args.mesh == "local"
            else make_production_mesh(multi_pod=args.mesh == "multi"))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed), dtype,
                           max_seq=args.seq)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10,
                                                             1),
                                total_steps=args.steps)
    opt_state = adamw.init(params)

    start = 0
    if args.ckpt_dir:
        last = CKPT.latest_step(args.ckpt_dir)
        if last is not None:
            state = CKPT.restore({"params": params, "opt": opt_state},
                                 CKPT.step_path(args.ckpt_dir, last))
            params, opt_state = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  seed=args.seed))
    stub_rng = np.random.default_rng(args.seed)

    step_fn = make_train_step(cfg, opt_cfg, remat=args.remat)
    p_specs = SH.param_specs(params, mesh)
    with mesh:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.time()
        losses = []
        for step in range(start, args.steps):
            batch = data.batch(step)
            batch.update(frontend_stub(cfg, args.batch, stub_rng))
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({time.time()-t0:.1f}s)")
            if args.ckpt_dir and args.ckpt_every \
                    and (step + 1) % args.ckpt_every == 0:
                CKPT.save({"params": params, "opt": opt_state},
                          CKPT.step_path(args.ckpt_dir, step + 1))
    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
